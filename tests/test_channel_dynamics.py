"""Round-coupled channel dynamics: Gauss-Markov fading state in the scan
carry, selection-driven cross-cell interference inside one traced program,
the interference-folding exactly-once invariant, and the cohort/channel
bugfix sweep (trace-safe Fleet.num_cells, empty-selection masked_max,
cohort-axis padding)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ALLOCATORS, CHANNELS, ExperimentSpec, FleetSpec,
                       build_cohort, build_experiment, build_fleet,
                       multicell_fleet_spec)
from repro.api.scenario import _gm_init, _gm_step
from repro.core.baselines import equal_bandwidth, fedl_lambda
from repro.core.cohort import _mesh_pad, cohort_mesh
from repro.core.sao import solve_sao
from repro.core.wireless import (effective_arrays, fleet_arrays, masked_max,
                                 sample_fleet)

TINY = dict(dataset="fashion", clients=8, samples_per_client=16,
            train_samples=160, test_samples=80, local_iters=2, batch_size=8,
            rounds=2, devices_per_round=4, num_clusters=4,
            learning_rate=0.05)


# ---------------------------------------------------------------------------
# gauss-markov: AR(1) fading state
# ---------------------------------------------------------------------------


def test_gauss_markov_resolve_and_validation():
    gm = CHANNELS.resolve("gauss-markov:0.7")
    assert gm.rho == 0.7 and gm.traceable and gm.needs_rng and gm.stateful
    with pytest.raises(ValueError, match="rho"):
        CHANNELS.resolve("gauss-markov:1.5")
    # rayleigh-block is the pinned rho=0 special case; its ':arg' is floor
    rb = CHANNELS.resolve("rayleigh-block:0.01")
    assert rb.rho == 0.0 and rb.floor == 0.01 and rb.stateful
    assert "rho" not in rb.params()          # init=False field, spec-stable


def test_gauss_markov_unit_mean_and_correlation():
    arr = {"J": jnp.ones((4000,), jnp.float32)}
    key = jax.random.PRNGKey(0)
    h = _gm_init(key, arr)
    assert h.shape == (4000, 2)
    # stationary unit-mean power at every lag
    gains = []
    for i in range(6):
        h, out = _gm_step(0.9, 0.0, jax.random.PRNGKey(i + 1), h, arr)
        gains.append(np.asarray(out["J"]))
    for g in gains:
        assert abs(float(np.mean(g)) - 1.0) < 0.1
    # rho=0.9 -> strong round-to-round correlation; rho=0 -> none
    corr_hi = np.corrcoef(gains[-2], gains[-1])[0, 1]
    h0 = _gm_init(key, arr)
    _, a = _gm_step(0.0, 0.0, jax.random.PRNGKey(11), h0, arr)
    _, b = _gm_step(0.0, 0.0, jax.random.PRNGKey(12), h0, arr)
    corr_lo = np.corrcoef(np.asarray(a["J"]), np.asarray(b["J"]))[0, 1]
    assert corr_hi > 0.6 and abs(corr_lo) < 0.1


@pytest.mark.slow
def test_gauss_markov_zero_rho_is_rayleigh_block_bit_identical():
    """Parity pin: gauss-markov:0 and rayleigh-block share one
    implementation, so the scanned histories match bit for bit."""
    gm = ExperimentSpec(**{**TINY, "rounds": 3},
                        fleet=FleetSpec(channel="gauss-markov:0.0"))
    rb = ExperimentSpec(**{**TINY, "rounds": 3},
                        fleet=FleetSpec(channel="rayleigh-block"))
    h_gm = build_experiment(gm).run()
    h_rb = build_experiment(rb).run()
    assert h_gm.accuracy == h_rb.accuracy
    assert h_gm.T_k == h_rb.T_k
    assert h_gm.E_k == h_rb.E_k


@pytest.mark.slow
def test_gauss_markov_correlated_fading_in_the_scan():
    spec = ExperimentSpec(**{**TINY, "rounds": 4},
                          fleet=FleetSpec(channel="gauss-markov:0.9"))
    exp = build_experiment(spec)
    assert exp.traceable()
    hist = exp.run()                       # scanned path, state in carry
    assert len(hist.T_k) == 5
    assert all(np.isfinite(hist.T_k)) and all(t > 0 for t in hist.T_k)
    assert len({round(t, 9) for t in hist.T_k}) > 1
    # host loop has no stateful-channel equivalent
    forced = build_experiment(spec)
    forced.traceable = lambda *a, **k: False
    with pytest.raises(ValueError, match="gauss-markov"):
        forced.run()


@pytest.mark.slow
def test_gauss_markov_runs_on_cohort_engine():
    spec = ExperimentSpec(**TINY, cohort=2,
                          fleet=FleetSpec(channel="gauss-markov:0.8"))
    ch = build_cohort(spec).run(transfer_guard=True)
    assert ch.accuracy.shape == (2, TINY["rounds"] + 1)
    assert np.all(np.isfinite(ch.accuracy)) and np.all(ch.T_k > 0)


# ---------------------------------------------------------------------------
# multicell-dynamic: selection-driven interference inside the scan
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_single_cell_dynamic_is_static_bit_identical():
    """Parity pin: with one cell there is nobody to interfere — the
    dynamic channel must be bit-identical to ``static``."""
    dyn = build_experiment(ExperimentSpec(
        **TINY, fleet=FleetSpec(channel="multicell-dynamic")))
    sta = build_experiment(ExperimentSpec(**TINY, fleet=FleetSpec()))
    h_d, h_s = dyn.run(), sta.run()
    assert h_d.accuracy == h_s.accuracy
    assert h_d.T_k == h_s.T_k
    assert h_d.E_k == h_s.E_k


@pytest.mark.slow
def test_multicell_dynamic_full_participation_matches_static_load():
    """Parity pin: when every device of every cell participates each
    round, the per-round interference sum equals the build-time
    average-load model at load = N (sum = N · mean)."""
    n = 6
    shared = {**TINY, "clients": n, "devices_per_round": n,
              "num_clusters": 2}
    dyn = ExperimentSpec(**shared, selection="random",
                         fleet=multicell_fleet_spec(
                             2, channel="multicell-dynamic"))
    sta = ExperimentSpec(**shared, selection="random",
                         fleet=multicell_fleet_spec(
                             2, channel=f"multicell-interference:{n}.0"))
    ch_d = build_cohort(dyn).run()
    ch_s = build_cohort(sta).run()
    # same PRNG stream, same selections, same training -> same accuracy
    np.testing.assert_array_equal(ch_d.accuracy, ch_s.accuracy)
    # and the dynamically-summed inr reproduces the static delays/energy
    np.testing.assert_allclose(ch_d.T_k, ch_s.T_k, rtol=1e-4)
    np.testing.assert_allclose(ch_d.E_k, ch_s.E_k, rtol=1e-4)
    assert ch_d.inr is not None and np.all(ch_d.inr > 0)


@pytest.mark.slow
def test_multicell_dynamic_inr_responds_to_selections():
    """Acceptance: geometry and gains are frozen (no fading), so any
    round-to-round inr variation can only come from which devices the
    other cells selected."""
    spec = ExperimentSpec(**{**TINY, "rounds": 4}, selection="random",
                          fleet=multicell_fleet_spec(
                              2, channel="multicell-dynamic"))
    ch = build_cohort(spec).run(transfer_guard=True)
    assert ch.inr is not None and ch.inr.shape == (2, 4)
    assert np.all(ch.inr > 0)
    # a 4-of-8 random draw varies round to round -> so must the inr
    assert len({round(float(v), 9) for v in ch.inr[0]}) > 1
    # ... and the delays feel it
    assert np.all(np.isfinite(ch.T_k)) and np.all(np.asarray(ch.T_k) > 0)


@pytest.mark.slow
def test_dynamic_interference_plus_gauss_markov_one_program():
    """Acceptance: a ≥2-cell experiment with BOTH selection-driven
    interference and Gauss-Markov correlated fading runs as a single
    compiled scan on the cohort engine — the transfer guard turns any
    per-round host round-trip into an error."""
    fleet = multicell_fleet_spec(2, channel={
        "name": "multicell-dynamic", "params": {"rho": 0.9}})
    ch_model = CHANNELS.resolve(fleet.channel)
    assert ch_model.stateful and ch_model.needs_rng and ch_model.dynamic
    spec = ExperimentSpec(**{**TINY, "rounds": 3}, fleet=fleet)
    ch = build_cohort(spec).run(transfer_guard=True)
    assert ch.accuracy.shape == (2, 4)
    assert np.all(np.isfinite(ch.accuracy))
    assert ch.inr is not None and ch.inr.shape == (2, 3)
    assert np.all(ch.inr > 0)
    # fading varies T round-to-round on top of the interference coupling
    assert len({round(float(t), 9) for t in np.asarray(ch.T_k)[0]}) > 1
    # spec round-trips with the combined channel params
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec


@pytest.mark.slow
def test_multicell_static_lane_matches_single_cell_run():
    """The cells axis moved INSIDE the traced program; each static-
    interference cell must still reproduce its stand-alone single run."""
    spec = ExperimentSpec(**TINY, fleet=multicell_fleet_spec(2))
    ch = build_cohort(spec).run()
    for c in range(2):
        single = build_experiment(spec, cell=c).run()
        lane = ch.history(c)
        assert lane.accuracy == single.accuracy
        np.testing.assert_allclose(lane.T_k, single.T_k, rtol=1e-6)
        np.testing.assert_allclose(lane.E_k, single.E_k, rtol=1e-6)


@pytest.mark.slow
def test_dynamic_channel_refuses_single_cell_view_of_multicell_fleet():
    spec = ExperimentSpec(**TINY, fleet=multicell_fleet_spec(
        2, channel="multicell-dynamic"))
    exp = build_experiment(spec, cell=0)
    with pytest.raises(ValueError, match="CohortRunner"):
        exp.run()


# ---------------------------------------------------------------------------
# interference folding: exactly once, everywhere (the pop invariant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["sao", "equal", "fedl:1.0", "fedl_auto:4"])
def test_interference_folded_exactly_once(name):
    """``SAOAllocator.allocate_traced`` folds at entry and ``solve_sao``
    folds again — safe ONLY because ``effective_arrays`` pops the ``inr``
    key. Pin that invariant for every allocator, host and traced."""
    fl = build_fleet(multicell_fleet_spec(2), 1, clients=8)
    # 5 of cell 0's devices: a selection every baseline can satisfy
    arr = fleet_arrays(fl.cell_fleet(0).select(np.arange(5)))
    assert float(jnp.max(arr["inr"])) > 0
    alloc = ALLOCATORS.resolve(name)

    T_a, E_a, _, _ = alloc.allocate_traced(arr, 20.0, None)
    pre = effective_arrays(arr)            # manually pre-folded
    assert "inr" not in pre
    T_b, E_b, _, _ = alloc.allocate_traced(pre, 20.0, None)
    np.testing.assert_allclose(float(T_a), float(T_b), rtol=1e-6)
    np.testing.assert_allclose(float(E_a), float(E_b), rtol=1e-6)
    # the host contract applies the same single fold
    host = alloc.allocate(arr, 20.0)
    np.testing.assert_allclose(float(host.T), float(T_a), rtol=1e-6)
    # a genuine double fold is NOT a no-op — the popped key is what
    # prevents it from ever happening
    double = dict(arr)
    double["J"] = pre["J"]
    T_d, _, _, _ = alloc.allocate_traced(double, 20.0, None)
    assert not np.isclose(float(T_d), float(T_a), rtol=1e-4)


def test_effective_arrays_idempotent():
    fl = build_fleet(multicell_fleet_spec(2), 0, clients=6)
    arr = fleet_arrays(fl.cell_fleet(0))
    once = effective_arrays(arr)
    twice = effective_arrays(dict(once))
    assert set(once) == set(twice) and "inr" not in once
    np.testing.assert_array_equal(np.asarray(once["J"]),
                                  np.asarray(twice["J"]))
    # dicts without inr (hand-built, pre-scenario) pass through untouched
    plain = {k: v for k, v in arr.items() if k != "inr"}
    assert effective_arrays(plain) is plain


# ---------------------------------------------------------------------------
# bugfix: empty-selection guard (masked_max / equal_bandwidth)
# ---------------------------------------------------------------------------


def test_masked_max_empty_guard():
    x = jnp.asarray([1.0, 2.0])
    assert float(masked_max(x)) == 2.0
    assert float(masked_max(x, jnp.asarray([True, False]))) == 1.0
    assert float(masked_max(x, jnp.zeros(2, bool))) == 0.0
    assert float(masked_max(x, jnp.zeros(2, bool), empty=-1.0)) == -1.0


def test_empty_selection_does_not_poison_allocators():
    arr = fleet_arrays(sample_fleet(5, seed=0))
    none = jnp.zeros(5, bool)
    r = equal_bandwidth(arr, 20.0, mask=none)
    # pre-fix this returned T = -inf and poisoned the scanned history
    assert float(r.T) == 0.0 and float(jnp.sum(r.e)) == 0.0
    s = solve_sao(arr, 20.0, mask=none)
    assert np.isfinite(float(s.T))
    assert np.all(np.asarray(s.b) == 0) and np.all(np.asarray(s.f) == 0)
    f = fedl_lambda(arr, 20.0, 1.0, mask=none)
    assert np.isfinite(float(f.T))


# ---------------------------------------------------------------------------
# bugfix: Fleet.num_cells is trace-safe host metadata
# ---------------------------------------------------------------------------


def test_with_power_rescales_cross_gains():
    """xgain bakes the transmit power in (X ∝ p_n); a power sweep on a
    dynamic fleet must not interfere with stale powers."""
    fl = build_fleet(multicell_fleet_spec(2, channel="multicell-dynamic"),
                     0, clients=4)
    doubled = fl.with_power(fl.p * 2.0)
    np.testing.assert_allclose(doubled.xgain, fl.xgain * 2.0)
    # single-cell / static fleets keep xgain=None through the sweep
    assert sample_fleet(3).with_power(0.1).xgain is None


def test_fleet_num_cells_trace_safe():
    fl = build_fleet(multicell_fleet_spec(2), 0, clients=6)
    assert fl.num_cells == 2
    # jitted functions taking a Fleet can consult num_cells: pre-fix this
    # raised (np.max on a tracer) / forced a host sync
    out = jax.jit(lambda f: jnp.asarray(f.inr) * f.num_cells)(fl)
    assert out.shape == (12,)
    # vmapped too (all leaves are tracers; the count rides the static aux)
    stacked = jax.tree_util.tree_map(lambda *x: jnp.stack(x), fl, fl)
    r = jax.vmap(lambda f: jnp.sum(jnp.asarray(f.h)) * f.num_cells)(stacked)
    assert r.shape == (2,)
    # sub-fleets keep the parent topology's count
    assert fl.cell_fleet(1).num_cells == 2
    assert fl.select(np.arange(3)).num_cells == 2
    assert sample_fleet(4, seed=0).num_cells == 1


# ---------------------------------------------------------------------------
# bugfix: cohort axis pads up to the device count (no idle devices)
# ---------------------------------------------------------------------------


def test_mesh_pad_arithmetic():
    class Stub:
        devices = np.zeros(6)

    assert _mesh_pad(8, Stub()) == 4       # the ISSUE's 8-lanes-6-devices
    assert _mesh_pad(12, Stub()) == 0
    assert _mesh_pad(5, Stub()) == 1
    assert _mesh_pad(3, None) == 0
    # single-device hosts (this container) never build a mesh
    if len(jax.devices()) == 1:
        assert cohort_mesh(8) is None


@pytest.mark.slow
def test_cohort_pads_and_strips_on_forced_multi_device():
    """3 seeds on 2 forced host devices: pre-fix the mesh degenerated to a
    single device (largest divisor of 3 is 1) and ran all seeds
    sequentially; now the axis pads to 4, shards over both devices, and
    the pad lane is stripped from the history."""
    code = textwrap.dedent("""
        import numpy as np
        import jax
        assert len(jax.devices()) == 2, jax.devices()
        import repro.core.cohort as cohort
        from repro.api import ExperimentSpec, build_cohort
        mesh = cohort.cohort_mesh(3)
        assert mesh is not None and mesh.devices.size == 2
        assert cohort._mesh_pad(3, mesh) == 1
        TINY = dict(dataset="fashion", clients=6, samples_per_client=8,
                    train_samples=96, test_samples=48, local_iters=1,
                    batch_size=4, rounds=1, devices_per_round=3,
                    num_clusters=3, learning_rate=0.05)
        spec = ExperimentSpec(**TINY, cohort=3)
        ch = build_cohort(spec).run()
        assert ch.accuracy.shape == (3, 2), ch.accuracy.shape
        assert ch.seeds == [0, 1, 2]
        assert np.all(np.isfinite(ch.accuracy))
        # the sharded+padded program reproduces the plain vmap
        cohort.cohort_mesh = lambda *a, **k: None
        ch2 = build_cohort(spec).run()
        np.testing.assert_allclose(ch.accuracy, ch2.accuracy, atol=1e-6)
        np.testing.assert_allclose(ch.T_k, ch2.T_k, rtol=1e-5)
        print("PAD-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "PAD-OK" in out.stdout, out.stdout + "\n" + out.stderr
