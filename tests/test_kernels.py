"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.pairwise_l2 import pairwise_l2
from repro.kernels.ssd_scan import ssd_scan

# the explicit use_pallas=True sweeps below are deliberate interpret-mode
# validation runs — the dispatch guard's off-TPU warning is expected noise
# (pytest.warns in the dispatch-policy tests still catches it: the warns
# context forces "always" over module marks)
pytestmark = pytest.mark.filterwarnings(
    "ignore:.*interpret mode.*:RuntimeWarning")


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# pairwise_l2
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m,f", [(7, 3, 33), (100, 10, 777), (128, 128, 512),
                                   (65, 129, 1000), (1, 1, 8), (300, 5, 2240)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2(n, m, f, dtype):
    kx, kc = jax.random.split(jax.random.PRNGKey(n * 1000 + m))
    x = jax.random.normal(kx, (n, f), dtype)
    c = jax.random.normal(kc, (m, f), dtype)
    out = pairwise_l2(x, c)
    want = ref.pairwise_l2_ref(x, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-1 if dtype == jnp.bfloat16 else 1e-3)


def test_pairwise_l2_self_distance_zero():
    x = jax.random.normal(jax.random.PRNGKey(0), (17, 123))
    d = pairwise_l2(x, x)
    assert float(jnp.max(jnp.abs(jnp.diagonal(d)))) < 1e-3


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sq,sk,causal,window", [
    (64, 64, True, None), (100, 100, True, None), (128, 128, False, None),
    (64, 64, True, 16), (33, 170, True, None), (1, 257, True, None),
    (96, 96, True, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(sq, sk, causal, window, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(sq * 7 + sk), 3)
    B, H, D = 2, 3, 32
    q = jax.random.normal(k1, (B, H, sq, D), dtype)
    k = jax.random.normal(k2, (B, H, sk, D), dtype)
    v = jax.random.normal(k3, (B, H, sk, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_gqa_wrapper():
    """ops.attention repeats KV heads for GQA and matches the oracle."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, K, D = 2, 64, 8, 2, 16
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, K, D))
    v = jax.random.normal(k3, (B, S, K, D))
    out = ops.attention(q, k, v, use_pallas=True)
    want = ops.attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,h,p,n,g,chunk", [
    (64, 4, 32, 16, 1, 16), (100, 4, 32, 16, 2, 32), (37, 2, 16, 8, 1, 64),
    (256, 8, 64, 32, 1, 64), (16, 2, 8, 8, 2, 16),
])
def test_ssd_scan(s, h, p, n, g, chunk):
    keys = jax.random.split(jax.random.PRNGKey(s + h), 4)
    B = 2
    x = jax.random.normal(keys[0], (B, s, h, p)) * 0.5
    a = -jax.nn.softplus(jax.random.normal(keys[1], (B, s, h)))
    bm = jax.random.normal(keys[2], (B, s, g, n)) * 0.3
    cm = jax.random.normal(keys[3], (B, s, g, n)) * 0.3
    y_k, h_k = ops.ssd(x, a, bm, cm, chunk=chunk, use_pallas=True)
    y_r, h_r = ops.ssd(x, a, bm, cm, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_matches_layer_decode():
    """Chunked SSD == step-by-step decode recurrence (cross-check of the
    two paths the models actually use)."""
    from repro.models import layers as L
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("mamba2-130m")
    pkey = jax.random.PRNGKey(3)
    p = L.init_mamba2(pkey, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 24, cfg.d_model)) * 0.3
    full = L.mamba2_apply(p, x, cfg)
    s = cfg.ssm
    d_inner, n_heads, conv_ch = L.mamba2_split_dims(cfg)
    ssm_state = jnp.zeros((2, n_heads, s.head_dim, s.d_state))
    conv_state = jnp.zeros((2, s.conv_width - 1, conv_ch))
    outs = []
    for t in range(x.shape[1]):
        y, ssm_state, conv_state = L.mamba2_decode(p, x[:, t], cfg,
                                                   ssm_state, conv_state)
        outs.append(y)
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# dispatch policy: REPRO_FORCE_PALLAS escape hatch + off-TPU warning
# ---------------------------------------------------------------------------


_SMALL = (jax.random.normal(jax.random.PRNGKey(11), (6, 32)),
          jnp.abs(jax.random.normal(jax.random.PRNGKey(12), (6,))) + 0.1)


@pytest.mark.skipif(ops._on_tpu(), reason="dispatch warning is off-TPU only")
def test_explicit_pallas_off_tpu_warns(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    flat, w = _SMALL
    with pytest.warns(RuntimeWarning, match="REPRO_FORCE_PALLAS"):
        got = ops.flat_aggregate(flat, w, use_pallas=True)
    want = ops.flat_aggregate(flat, w, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.skipif(ops._on_tpu(), reason="dispatch warning is off-TPU only")
@pytest.mark.parametrize("kwargs", [{}, {"use_pallas": None},
                                    {"use_pallas": False}])
def test_default_dispatch_off_tpu_is_silent(monkeypatch, kwargs, recwarn):
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    flat, w = _SMALL
    ops.flat_aggregate(flat, w, **kwargs)
    ops.client_divergence(flat, flat[0])
    assert not [x for x in recwarn if x.category is RuntimeWarning]


@pytest.mark.skipif(ops._on_tpu(), reason="dispatch warning is off-TPU only")
def test_force_env_silences_warning_and_flips_default(monkeypatch, recwarn):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    assert ops._force_pallas()
    assert ops._resolve_use_pallas("flat_aggregate", None) is True
    flat, w = _SMALL
    got = ops.flat_aggregate(flat, w, use_pallas=True)   # no warning now
    assert not [x for x in recwarn if x.category is RuntimeWarning]
    want = ops.flat_aggregate(flat, w, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("val", ["", "0", "false", "no", "False", "NO"])
def test_force_env_falsey_values(monkeypatch, val):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", val)
    assert not ops._force_pallas()


# ---------------------------------------------------------------------------
# chunked streaming reductions == fused ops (bitwise: row-independent math)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,p,chunk", [(10, 64, 3), (7, 33, 7), (16, 128, 5),
                                       (1, 8, 4), (33, 256, 32)])
def test_chunked_divergence_bitwise(n, p, chunk):
    kx, kg = jax.random.split(jax.random.PRNGKey(n + p))
    rows = jax.random.normal(kx, (n, p))
    gvec = jax.random.normal(kg, (p,))
    want = np.asarray(ops.client_divergence(rows, gvec))
    got = np.asarray(ops.chunked_client_divergence(rows, gvec,
                                                   chunk_size=chunk))
    assert np.array_equal(got, want)
    # iterable-of-blocks input (the paged store's iter_chunks contract)
    blocks = [rows[i:i + chunk] for i in range(0, n, chunk)]
    got_it = np.asarray(ops.chunked_client_divergence(iter(blocks), gvec))
    assert np.array_equal(got_it, want)


@pytest.mark.parametrize("n,m,p,chunk", [(10, 3, 64, 3), (33, 5, 100, 8),
                                         (8, 8, 32, 8), (5, 2, 16, 11)])
def test_chunked_pairwise_bitwise(n, m, p, chunk):
    kx, kc = jax.random.split(jax.random.PRNGKey(n * 10 + m))
    rows = jax.random.normal(kx, (n, p))
    cents = jax.random.normal(kc, (m, p))
    # jitted reference: the chunked path runs each block under jit, and
    # jit/eager fuse the ‖x‖²+‖c‖²−2x·c expansion differently
    want = np.asarray(jax.jit(ops.pairwise_sq_dists)(rows, cents))
    got = np.asarray(ops.chunked_pairwise(rows, cents, chunk_size=chunk))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    if chunk >= n:        # single block IS the jitted fused op: bitwise
        assert np.array_equal(got, want)


def test_streaming_weighted_mean_matches_aggregate():
    from repro.kernels.chunked import streaming_weighted_mean
    kx, kw = jax.random.split(jax.random.PRNGKey(5))
    rows = jax.random.normal(kx, (12, 96))
    w = jnp.abs(jax.random.normal(kw, (12,))) + 0.1
    want = np.asarray(ops.flat_aggregate(rows, w))
    blocks = ((rows[i:i + 5], w[i:i + 5]) for i in range(0, 12, 5))
    got = np.asarray(streaming_weighted_mean(blocks, rows.shape[1]))
    # summation order differs across waves: close, documented NOT bitwise
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_default_chunk_size_bounds():
    from repro.kernels.chunked import DEFAULT_CHUNK_BYTES, default_chunk_size
    assert default_chunk_size(1) == 8192               # hi clamp
    assert default_chunk_size(1 << 20) == 64           # lo clamp (4 MB rows)
    mid = default_chunk_size(65_536)                   # 256 KB rows
    assert 64 <= mid <= 8192
    assert abs(mid * 65_536 * 4 - DEFAULT_CHUNK_BYTES) <= 65_536 * 4
