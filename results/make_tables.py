"""Render EXPERIMENTS.md tables from the dry-run JSONL artifacts."""
import json
import sys


def load(path):
    try:
        return [json.loads(l) for l in open(path)]
    except FileNotFoundError:
        return []


def merge(scan_rows, twin_rows):
    """Twin rows carry roofline terms; scan rows carry memory. Twin files
    already merge both (run_one with twin=True), so prefer them."""
    by_key = {}
    for r in scan_rows:
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    for r in twin_rows:
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    return by_key


def fmt_mem(r):
    pm = r.get("peak_memory_per_device")
    return f"{pm/1e9:.1f}" if pm else "?"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | compile[s] | peak mem/dev [GB] | "
           "fits 16GB |", "|---|---|---|---|---|---|"]
    for r in rows:
        pm = r.get("peak_memory_per_device") or 0
        fits = "yes" if pm and pm <= 16e9 else ("NO" if pm else "?")
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                   f"{r['compile_s']} | {fmt_mem(r)} | {fits} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | compute[s] | memory[s] | collective[s] | "
           "bottleneck | useful | peak mem [GB] |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "twin_compile_s" not in r:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | {fmt_mem(r)} |")
    return "\n".join(out)


if __name__ == "__main__":
    scan_s = load("results/dryrun_scan_single.jsonl")
    scan_m = load("results/dryrun_scan_multi.jsonl")
    twin = load("results/dryrun_twin_single.jsonl")
    merged = merge(scan_s, twin)
    print("## single-pod (16x16 = 256 chips)\n")
    print(dryrun_table(list(merged.values())))
    print("\n## multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(scan_m))
    print("\n## roofline (single-pod, from unrolled twins)\n")
    print(roofline_table(list(merged.values())))
