"""Perf hillclimb driver (§Perf): run the three chosen (arch × shape) pairs
through lever sequences, appending annotated records to
results/hillclimb.jsonl.

  PYTHONPATH=src python results/hillclimb.py [--pair A|B|C|seamless] [--iter N]
"""
import argparse
import json
import sys
import time

sys.path.insert(0, "src")

from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS first)
from repro.launch.fl_round import lower_fl_round  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.roofline.analysis import analyze_compiled  # noqa: E402
from repro.configs.base import InputShape  # noqa: E402

OUT = "results/hillclimb.jsonl"

# iteration plans: (tag, hypothesis, run_one kwargs)
PAIRS = {
    "A": ("jamba-1.5-large-398b", "train_4k", [
        ("A0-baseline", "paper-faithful baseline: dense MoE, fp32 moments, "
         "no act constraints", {}),
        ("A1-dispatch-moe",
         "dense MoE evaluates all 16 experts (top-2 used) -> ~8x excess "
         "FLOPs on MoE layers and huge [T,E,F] intermediates; sort-based "
         "capacity dispatch should cut total FLOPs ~2-3x (MoE layers "
         "dominate) and slash peak memory",
         {"moe_impl": "dispatch"}),
        ("A2-bf16-moments",
         "Adam m,v are 3.2TB fp32 global (12.5GB/dev) -> bf16 moments "
         "halve optimizer state: peak -6GB/dev",
         {"moe_impl": "dispatch", "moment_dtype": "bfloat16"}),
        ("A3-act-constraints",
         "GSPMD picks replicated layouts for some [T,D] activations "
         "(involuntary-remat warnings); explicit batch-sharded constraints "
         "on block outputs should drop peak further",
         {"moe_impl": "dispatch", "moment_dtype": "bfloat16",
          "act_constraints": True}),
    ]),
    "B": ("qwen2-72b", "train_4k", [
        ("B0-baseline", "paper-faithful baseline", {}),
        ("B1-act-constraints",
         "334GB/dev peak with only 2.8GB of state -> activations/logits "
         "are replicated somewhere; constraining activations to "
         "batch-sharded and logits to (batch, vocab-model) layouts should "
         "cut peak several-fold and reduce all-gather bytes",
         {"act_constraints": True}),
        ("B2-bf16-moments",
         "moments 576GB fp32 global = 2.25GB/dev -> bf16 halves",
         {"act_constraints": True, "moment_dtype": "bfloat16"}),
        ("B3-qchunk2048",
         "q_chunk 512 -> 2048 quarters the lax.map trip count; HLO loop "
         "overhead and per-block collective launches shrink; VMEM tile "
         "grows but stays < v5e VMEM",
         {"act_constraints": True, "moment_dtype": "bfloat16",
          "q_chunk": 2048}),
    ]),
    "Afix": ("jamba-1.5-large-398b", "train_4k", [
        ("A2b-dense-bf16-moments",
         "A1 refuted: global argsort/gather/scatter in the dispatch path "
         "cannot be GSPMD-partitioned (sorts are global) -> collectives "
         "exploded 28->319s. Branch from the DENSE einsum (which shards "
         "cleanly on the expert axis) and attack the memory bottleneck "
         "instead: bf16 moments cut optimizer state 3.2TB->1.6TB "
         "(-6.2GB/dev)",
         {"moment_dtype": "bfloat16"}),
        ("A3b-dense-bf16-act",
         "add batch-sharded activation constraints: stop involuntary "
         "replication of [T,D] intermediates flagged by SPMD warnings",
         {"moment_dtype": "bfloat16", "act_constraints": True}),
    ]),
    "M": ("mixtral-8x22b", "train_4k", [
        ("M0-baseline", "most collective-bound pair in the baseline table "
         "(85.6s collective vs 50.6s memory vs 23.9s compute)", {}),
        ("M1-fused-gate-moe",
         "mixtral E=8 % 16 != 0 -> FFN-dim sharding; the down-proj psum "
         "then carries per-expert partials [T,E,D] = 8x the necessary "
         "bytes. Applying router gates BEFORE the (e,f) contraction "
         "reduces the cross-shard reduction to [T,D]: predict the "
         "collective term down ~3-5x (fwd+bwd both shrink)",
         {"moe_impl": "dense_fused"}),
        ("M2-fused+act",
         "add batch-sharded activation constraints to remove involuntary "
         "reshard collectives around attention reshapes",
         {"moe_impl": "dense_fused", "act_constraints": True}),
        ("M3-fused+act+bf16m",
         "moments 2x141B fp32 = 1.13TB global; bf16 halves -> peak "
         "-2.2GB/dev (memory-side cleanup once collectives are down)",
         {"moe_impl": "dense_fused", "act_constraints": True,
          "moment_dtype": "bfloat16"}),
    ]),
    "seamless": ("seamless-m4t-medium", "train_4k", [
        ("S0-baseline", "vocab 256206 % 16 != 0 -> lm_head replicated -> "
         "[B,S,V] logits replicated (67GB fp32)", {}),
        ("S1-pad-vocab",
         "pad physical vocab to a multiple of 128 (256256): logits shard "
         "16-way over model -> peak should drop ~10x on the logits path",
         {"pad_vocab": 128}),
        ("S2-pad+act",
         "add activation constraints on top",
         {"pad_vocab": 128, "act_constraints": True}),
    ]),
}


def run_pair(pair: str, only_iter=None):
    arch, shape, iters = PAIRS[pair]
    for i, (tag, hyp, kw) in enumerate(iters):
        if only_iter is not None and i != only_iter:
            continue
        print(f"### {tag}: {hyp[:90]}", flush=True)
        t0 = time.time()
        d = dryrun.run_one(arch, shape, "single", verbose=False, twin=True,
                           **kw)
        d["tag"] = tag
        d["hypothesis"] = hyp
        d["wall_s"] = round(time.time() - t0, 1)
        with open(OUT, "a") as f:
            f.write(json.dumps(d, default=str) + "\n")
        print(json.dumps({k: d[k] for k in
                          ("tag", "compute_s", "memory_s", "collective_s",
                           "bottleneck", "useful_ratio",
                           "peak_memory_per_device", "compile_s")},
                         indent=1, default=str), flush=True)


def run_fl_pair(only_iter=None):
    """Pair C: the paper's FL round (selection + aggregation) sharded over
    the single-pod mesh with tinyllama-1.1b clients."""
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config("tinyllama-1.1b")
    mesh = make_production_mesh(multi_pod=False)
    shape = InputShape("fl_round_128c", 0, 128, "decode")  # 128 clients
    iters = [
        ("C0-baseline",
         "full lm_head (65.5M dims) K-means features: the assignment "
         "matmul is 128x65.5Mx10 = 168 GFLOP and feats materialize 33GB "
         "fp32; divergence reductions stream all client weights", 0),
        ("C1-feature-slice-4096",
         "the paper's own w_fc2 insight at LM scale: cluster on a 4096-dim "
         "slice of lm_head -> assignment FLOPs down 16000x, feats "
         "materialization 16000x smaller; divergence (all layers) now "
         "dominates, collective mix should shift to the aggregation "
         "reduce", 4096),
    ]
    for i, (tag, hyp, fslice) in enumerate(iters):
        if only_iter is not None and i != only_iter:
            continue
        print(f"### {tag}", flush=True)
        t0 = time.time()
        lowered = lower_fl_round(cfg, mesh, num_clients=128,
                                 feature_slice=fslice)
        compiled = lowered.compile()
        rep = analyze_compiled(compiled, arch="fl_round/tinyllama-1.1b",
                               shape=shape, mesh_name="single", chips=256,
                               cfg=cfg, include_backward=False)
        d = rep.to_dict()
        # MODEL_FLOPS isn't meaningful for the scheduler step; override with
        # the useful work: divergence+aggregation ≈ 4 flops/param/client
        n = cfg.num_params()
        d["model_flops_global"] = 4.0 * n * 128
        d["tag"] = tag
        d["hypothesis"] = hyp
        d["wall_s"] = round(time.time() - t0, 1)
        try:
            ma = compiled.memory_analysis()
            d["peak_memory_per_device"] = float(
                ma.temp_size_in_bytes + ma.argument_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes)
        except Exception:
            pass
        with open(OUT, "a") as f:
            f.write(json.dumps(d, default=str) + "\n")
        print(json.dumps({k: d[k] for k in
                          ("tag", "compute_s", "memory_s", "collective_s",
                           "bottleneck", "peak_memory_per_device")},
                         indent=1, default=str), flush=True)
        del lowered, compiled


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="A", choices=list(PAIRS) + ["C"])
    ap.add_argument("--iter", type=int, default=None)
    args = ap.parse_args()
    if args.pair == "C":
        run_fl_pair(args.iter)
    else:
        run_pair(args.pair, args.iter)
