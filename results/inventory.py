"""Emit the §Inventory table for EXPERIMENTS.md (module/LOC census)."""
import os
import subprocess

ROOTS = ["src/repro", "tests", "benchmarks", "examples", "results"]


def loc(path):
    out = 0
    for dirpath, _, files in os.walk(path):
        for f in files:
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f)) as fh:
                    out += sum(1 for _ in fh)
    return out


if __name__ == "__main__":
    total = 0
    print("| package | python LOC |")
    print("|---|---|")
    for sub in sorted(os.listdir("src/repro")):
        p = os.path.join("src/repro", sub)
        if os.path.isdir(p):
            n = loc(p)
            total += n
            print(f"| src/repro/{sub} | {n} |")
    for r in ["tests", "benchmarks", "examples", "results"]:
        n = loc(r)
        total += n
        print(f"| {r} | {n} |")
    print(f"| **total** | **{total}** |")
