"""Render the §Perf hillclimb log from results/hillclimb.jsonl into
markdown (hypothesis → change → before → after → verdict)."""
import json
from collections import defaultdict


def main():
    rows = [json.loads(l) for l in open("results/hillclimb.jsonl")]
    by_pair = defaultdict(list)
    for r in rows:
        by_pair[r["tag"][0]].append(r)

    names = {"A": "Pair A — jamba-1.5-large-398b × train_4k (worst roofline)",
             "M": "Pair M — mixtral-8x22b × train_4k (most collective-bound)",
             "B": "Bonus — qwen2-72b × train_4k (worst dense)",
             "S": "Bonus — seamless-m4t-medium × train_4k (vocab divisibility)",
             "C": "Pair C — FL round × tinyllama-1.1b (the paper's technique)"}

    for key in ["A", "M", "C", "B", "S"]:
        seq = by_pair.get(key)
        if not seq:
            continue
        print(f"\n### {names.get(key, key)}\n")
        print("| iter | compute[s] | memory[s] | collective[s] | bottleneck "
              "| useful | peak/dev [GB] |")
        print("|---|---|---|---|---|---|---|")
        base = seq[0]
        for r in seq:
            pm = r.get("peak_memory_per_device")
            pm = f"{pm/1e9:.1f}" if pm else "?"
            print(f"| {r['tag']} | {r['compute_s']:.3g} | "
                  f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
                  f"{r['bottleneck']} | {r.get('useful_ratio', 0):.2f} | "
                  f"{pm} |")
        print()
        for prev, r in zip(seq, seq[1:]):
            dom_key = {"compute": "compute_s", "memory": "memory_s",
                       "collective": "collective_s"}[prev["bottleneck"]]
            before, after = prev[dom_key], r[dom_key]
            verdict = ("CONFIRMED" if after < before * 0.95 else
                       ("NEUTRAL" if after < before * 1.05 else "REFUTED"))
            delta = (1 - after / before) * 100 if before else 0
            print(f"- **{r['tag']}** — hypothesis: {r['hypothesis']}  \n"
                  f"  dominant term ({prev['bottleneck']}): "
                  f"{before:.3g}s → {after:.3g}s "
                  f"({delta:+.1f}% reduction) → **{verdict}**")


if __name__ == "__main__":
    main()
